"""Planner Pallas kernels vs their jnp oracles — bitwise.

The tropical-DP wavefront kernel and the fused link-geometry kernel
(ISSUE 9) must reproduce the planner's jnp hot loops EXACTLY: same
latencies, same first-argmin tie-breaks, same parent pointers, same
masking of failed UAVs.  Comparisons here are ``assert_array_equal`` —
bit equality, not tolerance — because the kernel path is advertised as a
drop-in program swap (``use_kernels``) whose plans must be
indistinguishable from the jnp path's.

Both sides of every comparison run under ``jax.jit``: XLA fuses
elementwise chains (with FMA on CPU) differently in an eager op-by-op
run, so jit-vs-eager can differ in the last ulp while jit-vs-jit — the
only configuration the planner ever runs — is exact.
"""
import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.kernels as kernels
from repro.core.batch import solve_chain_dp_batched, solve_chain_dp_multisource
from repro.core.channel import RadioParams
from repro.kernels import autotune, default_backend, resolve_interpret
from repro.kernels.link_geometry.ops import fused_link_geometry
from repro.kernels.link_geometry.ref import link_geometry_ref
from repro.kernels.tropical_dp.ops import dp_wavefront_step
from repro.kernels.tropical_dp.ref import dp_step_ref

PARAMS = RadioParams()
INF = np.inf


# ---------------------------------------------------------------------------
# operand builders
# ---------------------------------------------------------------------------


def dp_step_operands(seed, B=3, M=2, L=5, S=4, dead_frac=0.15):
    """Random wavefront-step operands with the solver's structure: dp row 0
    = [0, inf...], dead a = 0 row in tr, a sprinkling of inf (dead UAV /
    infeasible link) entries, and a coarse value grid so ties occur
    naturally on top of the crafted ones."""
    rng = np.random.default_rng(seed)
    dp = rng.integers(0, 8, (B, M, L, S + 1)).astype(np.float32)
    dp[:, :, 0, :] = INF
    dp[:, :, 0, 0] = 0.0
    tr = rng.integers(0, 5, (B, L, S, S + 1)).astype(np.float32)
    tr[:, 0] = INF                       # dead placeholder row
    tr0 = rng.integers(0, 5, (B, M, S)).astype(np.float32)
    for arr in (dp, tr, tr0):
        arr[rng.random(arr.shape) < dead_frac] = INF
    dp[:, :, 0, 0] = 0.0
    ct = rng.integers(0, 3, (L, S)).astype(np.float32)
    ok = (rng.random((L, S)) > 0.25).astype(np.float32)
    return [jnp.asarray(x) for x in (dp, tr, tr0, ct, ok)]


def geometry_operands(seed, B=4, U=6, with_gain=True, dead=True):
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0, 300, (B, U, 2)).astype(np.float32)
    active = np.ones((B, U), dtype=bool)
    if dead:
        active[rng.integers(0, B, 2), rng.integers(0, U, 2)] = False
    gain = None
    if with_gain:
        g = rng.uniform(0.5, 1.5, (B, U, U))
        gain = jnp.asarray((g + g.transpose(0, 2, 1)) / 2, jnp.float32)
    return jnp.asarray(pos), jnp.asarray(active), gain


# ---------------------------------------------------------------------------
# tropical-DP wavefront step
# ---------------------------------------------------------------------------


class TestTropicalDpStep:
    REF = staticmethod(jax.jit(dp_step_ref))

    def assert_step_parity(self, args, **blocks):
        row_r, pa_r, ps_r = self.REF(*args)
        row_k, pa_k, ps_k = dp_wavefront_step(*args, use_kernel=True,
                                              **blocks)
        np.testing.assert_array_equal(np.asarray(row_k), np.asarray(row_r))
        np.testing.assert_array_equal(np.asarray(pa_k), np.asarray(pa_r))
        np.testing.assert_array_equal(np.asarray(ps_k), np.asarray(ps_r))

    @pytest.mark.parametrize("seed", range(6))
    def test_bitwise_parity_random(self, seed):
        self.assert_step_parity(dp_step_operands(seed))

    @pytest.mark.parametrize("shape", [(1, 1, 2, 2), (2, 4, 3, 5),
                                       (5, 1, 8, 3), (2, 3, 4, 8)])
    def test_bitwise_parity_shapes(self, shape):
        B, M, L, S = shape
        self.assert_step_parity(dp_step_operands(99, B=B, M=M, L=L, S=S))

    @pytest.mark.parametrize("blocks", [dict(block_b=1),
                                        dict(block_m=1),
                                        dict(block_s=2),
                                        dict(block_b=1, block_m=1,
                                             block_s=2),
                                        dict(block_s=3)])  # snaps 3 -> 2
    def test_tiled_grids_match(self, blocks):
        """Multi-cell grids (interpret mode runs them sequentially) emit
        the same tiles as the whole-axis launch."""
        self.assert_step_parity(dp_step_operands(7, B=2, M=2, L=4, S=4),
                                **blocks)

    def test_first_argmin_tie_breaks(self):
        """Equal-cost candidates across BOTH reduction axes: the winner
        must be the lexicographically first (a, s0), exactly jnp.argmin's
        first-occurrence rule in the oracle's two-stage order."""
        B, M, L, S = 1, 1, 3, 3
        dp = np.full((B, M, L, S + 1), INF, np.float32)
        dp[:, :, 0, 0] = 0.0
        dp[0, 0, 1] = [INF, 2.0, 2.0, 2.0]       # s0 = 1, 2, 3 all tie
        dp[0, 0, 2] = [INF, 1.0, 1.0, INF]
        tr = np.full((B, L, S, S + 1), INF, np.float32)
        tr[0, 1, :, 1:] = 3.0                     # a = 1: every s0 ties
        tr[0, 2, :, 1:] = 4.0                     # a = 2: 1 + 4 = 2 + 3 tie
        tr0 = np.full((B, M, S), 5.0, np.float32)
        ct = np.zeros((L, S), np.float32)
        ok = np.ones((L, S), np.float32)
        args = [jnp.asarray(x) for x in (dp, tr, tr0, ct, ok)]
        row_k, pa_k, ps_k = dp_wavefront_step(*args, use_kernel=True)
        # candidates: a=0 -> 0+5=5; a=1 -> 2+3=5; a=2 -> 1+4=5: a=0 wins
        np.testing.assert_array_equal(np.asarray(row_k)[0, 0], 5.0)
        np.testing.assert_array_equal(np.asarray(pa_k)[0, 0], 0)
        np.testing.assert_array_equal(np.asarray(ps_k)[0, 0], 0)
        # kill the a=0 candidate: a=1 wins over the equal a=2, s0 first-min
        tr0[:] = INF
        args[2] = jnp.asarray(tr0)
        self.assert_step_parity(args)
        row_k, pa_k, ps_k = dp_wavefront_step(*args, use_kernel=True)
        np.testing.assert_array_equal(np.asarray(pa_k)[0, 0], 1)
        np.testing.assert_array_equal(np.asarray(ps_k)[0, 0], 1)

    def test_all_infeasible_matches_oracle(self):
        """Fully masked steps (dead fleet) keep argmin's all-inf -> index 0
        convention on both paths."""
        args = dp_step_operands(3)
        args[4] = jnp.zeros_like(args[4])         # ok = 0 everywhere
        self.assert_step_parity(args)
        row_k, pa_k, ps_k = dp_wavefront_step(*args, use_kernel=True)
        assert np.isinf(np.asarray(row_k)).all()
        np.testing.assert_array_equal(np.asarray(pa_k), 0)

    def test_compiled_mode_or_skip(self):
        """interpret=False must agree bitwise wherever the backend compiles
        Pallas (TPU/GPU); CPU refuses — skip, don't fail."""
        args = dp_step_operands(5, B=2, M=1, L=3, S=3)
        ref = dp_wavefront_step(*args, use_kernel=True, interpret=True)
        try:
            got = dp_wavefront_step(*args, use_kernel=True, interpret=False)
        except Exception:
            pytest.skip("backend does not compile Pallas kernels "
                        "(CPU supports interpret mode only)")
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# fused link geometry
# ---------------------------------------------------------------------------


class TestLinkGeometryKernel:
    REF = staticmethod(jax.jit(
        functools.partial(link_geometry_ref, params=PARAMS)))

    def assert_geometry_parity(self, pos, active, gain, **blocks):
        ref = self.REF(pos, active, gain)
        got = fused_link_geometry(pos, PARAMS, active=active,
                                  gain_scale=gain, use_kernel=True,
                                  **blocks)
        for name, a, b in zip(("dist", "threshold", "rate"), got, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)

    @pytest.mark.parametrize("seed", range(4))
    @pytest.mark.parametrize("with_gain", [False, True])
    def test_bitwise_parity(self, seed, with_gain):
        self.assert_geometry_parity(
            *geometry_operands(seed, with_gain=with_gain))

    def test_dead_uav_masking(self):
        """A failed UAV transmits nothing and anchors no pair feasibility
        — its rate rows/cols must match the oracle's masked solve."""
        pos, active, gain = geometry_operands(11, dead=False)
        active = np.asarray(active).copy()
        active[:, 2] = False                    # one UAV down everywhere
        active[0, :] = False                    # one scenario fully down
        self.assert_geometry_parity(pos, jnp.asarray(active), gain)

    @pytest.mark.parametrize("blocks", [dict(block_b=2),
                                        dict(block_u=3),
                                        dict(block_b=1, block_u=2),
                                        dict(block_u=4)])  # snaps 4 -> 3
    def test_tiled_grids_match(self, blocks):
        self.assert_geometry_parity(*geometry_operands(2), **blocks)

    def test_ref_equals_oracle_stage(self):
        """The ref IS the planner's current geometry stage — pin it to the
        four batch.py passes so kernel parity transitively reaches them."""
        from repro.core.batch import (pairwise_dist_batched,
                                      power_threshold_batched,
                                      rate_matrix_batched,
                                      solve_power_batched)
        pos, active, gain = geometry_operands(4)

        @jax.jit
        def staged(pos, active, gain):
            dist = pairwise_dist_batched(pos)
            th = power_threshold_batched(dist, PARAMS, gain_scale=gain)
            pw = solve_power_batched(dist, PARAMS, active=active,
                                     gain_scale=gain, threshold_matrix=th)
            rate = rate_matrix_batched(dist, pw.power, PARAMS,
                                       pw.link_feasible, gain_scale=gain)
            return dist, th, rate

        for a, b in zip(self.REF(pos, active, gain),
                        staged(pos, active, gain)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_direct_body_equals_pallas_launch(self):
        """On CPU the default dispatch skips the Pallas interpreter and
        runs the kernel body directly (``link_geometry_fused``); it must
        be bit-identical to the explicit ``pallas_call`` launch."""
        pos, active, gain = geometry_operands(8)
        for g in (gain, None):
            direct = fused_link_geometry(pos, PARAMS, active=active,
                                         gain_scale=g, use_kernel=True)
            launch = fused_link_geometry(pos, PARAMS, active=active,
                                         gain_scale=g, use_kernel=True,
                                         interpret=True)
            for a, b in zip(direct, launch):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_compiled_mode_or_skip(self):
        pos, active, gain = geometry_operands(6, B=2, U=4)
        ref = fused_link_geometry(pos, PARAMS, active=active,
                                  gain_scale=gain, interpret=True)
        try:
            got = fused_link_geometry(pos, PARAMS, active=active,
                                      gain_scale=gain, interpret=False)
        except Exception:
            pytest.skip("backend does not compile Pallas kernels "
                        "(CPU supports interpret mode only)")
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# solver wrappers: kernel path vs jnp path through the public API
# ---------------------------------------------------------------------------


def dp_problem(seed, B=5, U=5, L=6, symmetric=False):
    """A full chain-DP problem over a real rate matrix.  ``symmetric``
    collapses every device and every link to identical constants, so MANY
    placements tie exactly — the adversarial case for tie-break parity."""
    rng = np.random.default_rng(seed)
    pos, active, gain = geometry_operands(seed, B=B, U=U, with_gain=False)
    rate = np.array(link_geometry_ref(pos, active, gain,
                                      params=PARAMS)[2])
    if symmetric:
        off = ~np.eye(U, dtype=bool)
        rate[:, off] = 2e7                      # every live link identical
        rate[np.asarray(~active)] = 0.0
        rate[:, :, :][~np.asarray(active)[:, None, :]
                      .repeat(U, 1)] = 0.0
        rate[:, np.eye(U, dtype=bool)] = np.inf
    mk = (lambda n, lo, hi: np.full(n, lo) if symmetric
          else rng.uniform(lo, hi, n))
    return dict(compute=mk(L, 1e6, 5e6), memory=mk(L, 1e4, 1e5),
                act_bits=mk(L, 1e4, 1e5), input_bits=5e4,
                mem_cap=mk(U, 2e5, 6e5), compute_cap=mk(U, 1e7, 4e7),
                throughput=mk(U, 1e8, 5e8), rate=rate,
                active=np.asarray(active),
                source=rng.integers(0, U, B),
                sources=rng.integers(0, U, (B, 3)))


class TestSolverKernelPath:
    @pytest.mark.parametrize("seed,symmetric", [(0, False), (1, False),
                                                (2, True), (3, True)])
    def test_single_source_bitwise(self, seed, symmetric):
        p = dp_problem(seed, symmetric=symmetric)
        args = (p["compute"], p["memory"], p["act_bits"], p["input_bits"],
                p["mem_cap"], p["compute_cap"], p["throughput"], p["rate"],
                p["source"], p["active"])
        a0, l0 = solve_chain_dp_batched(*args)
        a1, l1 = solve_chain_dp_batched(*args, use_kernel=True)
        np.testing.assert_array_equal(a1, a0)
        np.testing.assert_array_equal(l1, l0)

    @pytest.mark.parametrize("seed,symmetric", [(4, False), (5, True)])
    def test_multi_source_bitwise(self, seed, symmetric):
        """The kernel's native slot axis vs the oracle's vmap — one launch
        per step must equal M independent solves, tie-breaks included."""
        p = dp_problem(seed, symmetric=symmetric)
        args = (p["compute"], p["memory"], p["act_bits"], p["input_bits"],
                p["mem_cap"], p["compute_cap"], p["throughput"], p["rate"],
                p["sources"], p["active"])
        a0, l0 = solve_chain_dp_multisource(*args)
        a1, l1 = solve_chain_dp_multisource(*args, use_kernel=True)
        np.testing.assert_array_equal(a1, a0)
        np.testing.assert_array_equal(l1, l0)

    def test_dead_uav_never_hosts(self):
        p = dp_problem(6)
        active = p["active"].copy()
        active[:, 1] = False
        a1, _ = solve_chain_dp_batched(
            p["compute"], p["memory"], p["act_bits"], p["input_bits"],
            p["mem_cap"], p["compute_cap"], p["throughput"], p["rate"],
            p["source"], active, use_kernel=True)
        assert (a1 != 1).all()


# ---------------------------------------------------------------------------
# engine plumbing: cache keys, retraces, rollout parity
# ---------------------------------------------------------------------------


class TestEngineKernelPath:
    @classmethod
    def _fixture(cls):
        from repro.configs.lenet import LENET
        from repro.core import RadioChannel, cnn_cost, make_devices
        return RadioChannel(PARAMS), make_devices(4), cnn_cost(LENET)

    def test_cache_two_misses_zero_retraces(self):
        """Mixing kernel and jnp engines is exactly 2 cache misses (one
        program each) and re-planning on either is 0 retraces; the flag is
        part of the key, so the two programs never collide."""
        from repro.runtime.scenario_engine import (PlanFnCache,
                                                   ScenarioEngine,
                                                   ScenarioGenerator)
        ch, devs, mc = self._fixture()
        cache = PlanFnCache()
        e0 = ScenarioEngine(ch, devs, mc, plan_cache=cache)
        e1 = ScenarioEngine(ch, devs, mc, plan_cache=cache,
                            use_kernels=True)
        assert e0._cache_key() != e1._cache_key()
        assert (cache.misses, cache.hits) == (2, 0)
        batch = ScenarioGenerator(np.full((4, 2), 30.0) +
                                  np.arange(8).reshape(4, 2),
                                  pos_sigma_m=5.0, seed=3).draw(4)
        p0, p1 = e0.plan_batch(batch), e1.plan_batch(batch)
        np.testing.assert_array_equal(p0.assign, p1.assign)
        np.testing.assert_array_equal(p0.latency, p1.latency)
        np.testing.assert_array_equal(p0.power, p1.power)
        traces = cache.trace_count()
        # same-config engines hit the cache and re-planning never retraces
        ScenarioEngine(ch, devs, mc, plan_cache=cache,
                       use_kernels=True).plan_batch(batch)
        assert cache.hits == 1
        assert cache.trace_count() == traces

    def test_rollout_bitwise_parity(self):
        """A full (B, T) fleet rollout — mobility, failures, battery, the
        multi-source stream — is bitwise identical under use_kernels."""
        from repro.core import RolloutSpec
        from repro.core.positions import hex_init
        from repro.runtime.fleet_rollout import FleetRollout
        from repro.runtime.scenario_engine import PlanFnCache
        ch, devs, mc = self._fixture()
        spec = RolloutSpec(frames=3, requests_per_frame=2,
                           jitter_sigma_m=2.0, failure_prob=0.2,
                           recovery_prob=0.3, battery_j=2e3,
                           hover_watts=0.05, frame_s=1.0)
        cache = PlanFnCache()
        base = hex_init(4, 40.0, jitter=1.0, seed=5)
        kw = dict(plan_cache=cache, seed=13)
        r0 = FleetRollout(ch, devs, mc, spec, **kw).run(
            base, n_trajectories=2)
        r1 = FleetRollout(ch, devs, mc, spec, use_kernels=True, **kw).run(
            base, n_trajectories=2)
        for f in ("latency", "total_power", "feasible", "cap_feasible",
                  "source_latency", "assign", "positions", "active",
                  "charge", "n_requests", "energy_tx", "energy_cmp"):
            np.testing.assert_array_equal(getattr(r0, f), getattr(r1, f),
                                          err_msg=f)


# ---------------------------------------------------------------------------
# resolve_interpret memoization + autotune table
# ---------------------------------------------------------------------------


class TestResolveInterpret:
    def test_backend_memoized_once_per_process(self, monkeypatch):
        """After the first probe the module never asks jax again — the
        per-pallas_call backend query was measurable overhead in the
        per-step kernel launches."""
        kernels._DEFAULT_BACKEND = None
        calls = []
        real = jax.default_backend

        def probe():
            calls.append(1)
            return real()

        monkeypatch.setattr(jax, "default_backend", probe)
        first = default_backend()
        for _ in range(5):
            assert default_backend() == first
            resolve_interpret(None)
        assert len(calls) == 1

    def test_explicit_override_beats_backend(self, monkeypatch):
        """A monkeypatched backend changes the default resolution but an
        explicit interpret= flag always wins."""
        monkeypatch.setattr(kernels, "_DEFAULT_BACKEND", "tpu")
        assert resolve_interpret(None) is False
        assert resolve_interpret(True) is True
        monkeypatch.setattr(kernels, "_DEFAULT_BACKEND", "cpu")
        assert resolve_interpret(None) is True
        assert resolve_interpret(False) is False

    def test_resolved_default_matches_live_backend(self):
        kernels._DEFAULT_BACKEND = None
        assert resolve_interpret(None) is (jax.default_backend() != "tpu")


class TestAutotune:
    def test_divisor_snapping(self):
        assert autotune.divisor_leq(12, 5) == 4
        assert autotune.divisor_leq(12, 6) == 6
        assert autotune.divisor_leq(7, 3) == 1     # prime: whole or 1
        assert autotune.divisor_leq(8, 100) == 8   # clamp to the axis
        assert autotune.divisor_leq(8, 0) == 1

    def test_lookup_fallback_chain(self):
        exact = autotune.lookup("tropical_dp", U=32, L=32, S=32,
                                dtype="float32", backend="tpu")
        assert exact == autotune.TABLE[
            ("tropical_dp", "tpu", 32, 32, 32, "float32")]
        generic = autotune.lookup("tropical_dp", U=999, L=1, S=999,
                                  dtype="float32", backend="tpu")
        assert generic == autotune.TABLE[("tropical_dp", "tpu")]
        assert autotune.lookup("no_such_kernel", U=4, dtype="float32",
                               backend="cpu") == {}

    def test_cpu_rows_request_whole_axes(self):
        """On CPU (interpret mode runs grid cells sequentially) the tuned
        choice is one cell — whole axes — so the kernel body vectorizes
        exactly like the jnp oracle."""
        for kernel in ("tropical_dp", "link_geometry"):
            tuned = autotune.lookup(kernel, U=16, L=8, S=16,
                                    dtype="float32", backend="cpu")
            assert tuned and all(v == 0 for v in tuned.values())
