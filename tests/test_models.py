"""Per-arch smoke tests (reduced configs, CPU) + decode consistency +
partition invariance for the paper's CNNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.alexnet import ALEXNET
from repro.configs.lenet import LENET
from repro.configs.registry import LM_ARCHS, get_arch
from repro.models import build_model
from repro.models.cnn import distributed_forward, forward, init_cnn

KEY = jax.random.PRNGKey(0)
B, S, CACHE = 2, 12, 24


def _inputs(cfg, key, s=S):
    toks = jax.random.randint(key, (B, s), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (B, s), 0,
                                cfg.vocab_size)
    extra = None
    if cfg.family == "audio":
        extra = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model))
    elif cfg.family == "vlm":
        extra = jax.random.normal(key, (B, cfg.vision_tokens, cfg.d_model))
    return toks, labels, extra


@pytest.mark.parametrize("arch", LM_ARCHS)
class TestArchSmoke:
    def test_train_step_shapes_and_finite(self, arch):
        """One forward/train step on CPU: finite loss, grads exist."""
        cfg = get_arch(arch).reduced()
        model = build_model(cfg)
        params = model.init(KEY)
        toks, labels, extra = _inputs(cfg, jax.random.PRNGKey(2))

        def loss_fn(p):
            if cfg.family == "audio":
                return model.train_loss(p, toks, labels, extra)
            if cfg.family == "vlm":
                return model.train_loss(p, toks, labels,
                                        extra_embeds=extra)
            return model.train_loss(p, toks, labels)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        assert jnp.isfinite(loss)
        gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in
                    jax.tree.leaves(grads))
        assert np.isfinite(gnorm) and gnorm > 0

    def test_decode_matches_prefill(self, arch):
        """Decoding token t with the cache == full forward logits at t."""
        cfg = get_arch(arch).reduced()
        model = build_model(cfg)
        params = model.init(KEY)
        toks, _, extra = _inputs(cfg, jax.random.PRNGKey(3))
        # vlm: absolute position includes the prepended vision tokens
        offset = cfg.vision_tokens if cfg.family == "vlm" else 0
        pos = jnp.full((B, 1), offset + S - 1, jnp.int32)
        if cfg.family == "audio":
            _, cache = model.prefill(params, toks[:, :S - 1], extra, CACHE)
            got, _ = model.decode_step(params, toks[:, S - 1:], pos, cache)
            want, _ = model.prefill(params, toks, extra, CACHE)
        elif cfg.family == "vlm":
            _, cache = model.prefill(params, toks[:, :S - 1], CACHE,
                                     extra_embeds=extra)
            got, _ = model.decode_step(params, toks[:, S - 1:], pos, cache)
            want, _ = model.prefill(params, toks, CACHE,
                                    extra_embeds=extra)
        else:
            _, cache = model.prefill(params, toks[:, :S - 1], CACHE)
            got, _ = model.decode_step(params, toks[:, S - 1:], pos, cache)
            want, _ = model.prefill(params, toks, CACHE)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("cfg,hw", [(LENET, 32), (ALEXNET, 227)])
class TestCNN:
    def test_forward_shape(self, cfg, hw):
        params = init_cnn(KEY, cfg)
        x = jax.random.normal(KEY, (2, hw, hw, 3))
        y = forward(cfg, params, x)
        n_cls = cfg.layers[-1].out_features
        assert y.shape == (2, n_cls)
        assert bool(jnp.all(jnp.isfinite(y)))

    def test_partition_invariance(self, cfg, hw):
        """Distributed (per-placement) execution == monolithic, exactly.

        This is the system-level invariant behind the paper's approach:
        latency changes with placement, the prediction must not."""
        params = init_cnn(KEY, cfg)
        x = jax.random.normal(KEY, (2, hw, hw, 3))
        y0 = forward(cfg, params, x)
        for n_dev in (2, 3, 5):
            assign = [j % n_dev for j in range(len(cfg.layers))]
            y1, transfers = distributed_forward(cfg, params, x, assign)
            np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
            assert transfers > 0


class TestRecurrentEquivalence:
    def test_mlstm_chunkwise_matches_sequential(self):
        from repro.models.recurrent import (mlstm_init, mlstm_seq,
                                            mlstm_seq_ref, mlstm_state)
        p = mlstm_init(KEY, 32, 2, 16)
        x = jax.random.normal(jax.random.PRNGKey(5), (2, 64, 32))
        st = mlstm_state(2, 2, 16)
        y_ref, st_ref = mlstm_seq_ref(p, x, st)
        for chunk in (8, 32, 64):
            y, st2 = mlstm_seq(p, x, st, chunk=chunk)
            np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                       atol=1e-4)
            np.testing.assert_allclose(np.asarray(st2["C"]),
                                       np.asarray(st_ref["C"]), atol=1e-4)

    def test_rglru_seq_matches_stepwise(self):
        from repro.models.recurrent import (rglru_block_apply,
                                            rglru_block_state, rglru_init)
        cfg_w, conv = 32, 4
        p = rglru_init(KEY, 16, cfg_w, conv)
        x = jax.random.normal(jax.random.PRNGKey(6), (2, 8, 16))
        st = rglru_block_state(2, cfg_w, conv, x.dtype, decode=False)
        y_seq, st_seq = rglru_block_apply(p, x, st)
        # step-by-step decode must reproduce the sequence outputs
        std = rglru_block_state(2, cfg_w, conv, x.dtype, decode=True)
        outs = []
        for t in range(8):
            y_t, std = rglru_block_apply(p, x[:, t:t + 1], std)
            std = dict(std, decode=True)
            outs.append(y_t)
        y_dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_seq),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(std["h"]),
                                   np.asarray(st_seq["h"]), atol=1e-4)
