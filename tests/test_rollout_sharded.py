"""Mesh-sharded fleet rollouts: shard-invariance harness (ISSUE 6).

The trajectory axis B of the (B, T) rollout is embarrassingly parallel, so
sharding it over a 1-D device mesh (``FleetRollout.run(mesh=|devices=)``)
must be INVISIBLE in every output: identical per-trajectory arrays,
identical aggregate statistics (the acceptance bound is <= 1e-6; on CPU
the shards are in fact bitwise equal), ragged B handled by padding plus
the ``RolloutTrace.valid`` mask, and zero retraces after each mesh's first
compile — with single-device and sharded programs living under DISTINCT
``PlanFnCache`` keys (the mesh signature) so they can never collide.

Multi-device cases need forced host devices on CPU::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest tests/test_rollout_sharded.py

which is exactly what the ``tier1-multidevice`` CI job sets for the whole
suite; under the plain single-device tier-1 run those cases skip with a
reason pointing here.
"""
import numpy as np
import pytest

import jax

from repro.configs.lenet import LENET
from repro.core import (PositionSpec, RadioChannel, RolloutSpec, cnn_cost,
                        make_devices)
from repro.core.positions import hex_init
from repro.parallel.sharding import fleet_mesh, mesh_signature
from repro.runtime.fleet_rollout import FleetRollout
from repro.runtime.scenario_engine import (PlanFnCache, ScenarioEngine,
                                           ScenarioGenerator)
from repro.runtime.serve_loop import PeriodicReplanner

CH = RadioChannel()
MC = cnn_cost(LENET)
N_DEV = jax.local_device_count()

# one rich dynamics config used everywhere: mobility + failures +
# recovery + battery drain + a 2-request multi-source stream, so the
# parity claim covers every branch of the frame body
SPEC = RolloutSpec(frames=4, requests_per_frame=2, jitter_sigma_m=2.0,
                   failure_prob=0.15, recovery_prob=0.25, battery_j=5e3,
                   hover_watts=0.5, frame_s=1.0)
U = 5
BASE = hex_init(U, 40.0, jitter=0.5, seed=1)

# every array a RolloutTrace carries, with its comparison mode
EXACT_FIELDS = ("feasible", "cap_feasible", "assign", "active",
                "n_requests")
CLOSE_FIELDS = ("latency", "total_power", "source_latency", "positions",
                "charge", "energy_tx", "energy_cmp")


def needs(n: int):
    return pytest.mark.skipif(
        N_DEV < n,
        reason=f"needs {n} devices, have {N_DEV}; run under "
               f"XLA_FLAGS=--xla_force_host_platform_device_count={n} "
               "(the tier1-multidevice CI job does)")


def make_rollout(cache, seed=3, position_spec=None):
    return FleetRollout(CH, make_devices(U), MC, SPEC, plan_cache=cache,
                        position_spec=position_spec, seed=seed)


def assert_traces_match(ref, got):
    """``got`` (possibly padded) equals the unsharded ``ref`` row-for-row
    on its valid trajectories, to the <= 1e-6 acceptance bound (inf
    patterns exact)."""
    sel = np.flatnonzero(got._valid())
    assert len(sel) == ref.latency.shape[0]
    for name in EXACT_FIELDS:
        np.testing.assert_array_equal(getattr(got, name)[sel],
                                      getattr(ref, name), err_msg=name)
    for name in CLOSE_FIELDS:
        a = getattr(ref, name)
        b = getattr(got, name)[sel]
        finite = np.isfinite(a)
        np.testing.assert_array_equal(np.isfinite(b), finite, err_msg=name)
        np.testing.assert_allclose(b[finite], a[finite], rtol=0, atol=1e-6,
                                   err_msg=name)
    # the aggregate statistics the acceptance criterion names
    for stat in ("feasibility_rate", "mean_latency", "mean_power"):
        assert abs(getattr(got, stat) - getattr(ref, stat)) <= 1e-6, stat
    for q in (50.0, 95.0):
        a, b = ref.latency_percentile(q), got.latency_percentile(q)
        assert (a == b) if not np.isfinite(a) else abs(a - b) <= 1e-6


class TestShardedParity:
    """Sharded-vs-single-device parity at device counts {1, 2, 8}."""

    B = 16

    def _reference(self, cache):
        return make_rollout(cache).run(BASE, n_trajectories=self.B)

    @pytest.mark.parametrize("n", [
        pytest.param(1),
        pytest.param(2, marks=needs(2)),
        pytest.param(8, marks=needs(8)),
    ])
    def test_parity_at_device_count(self, n):
        cache = PlanFnCache()
        ref = self._reference(cache)
        got = make_rollout(cache).run(BASE, n_trajectories=self.B,
                                      devices=n)
        assert_traces_match(ref, got)
        if n > 1:
            assert got.valid is None          # 16 divides n: no padding

    @needs(2)
    def test_parity_with_fused_p2(self):
        """The sharded scan embeds the SAME fused P2 warm-start path."""
        cache = PlanFnCache()
        pspec = PositionSpec(steps=50, repair_iters=25)
        ref = make_rollout(cache, position_spec=pspec).run(
            BASE, n_trajectories=4)
        got = make_rollout(cache, position_spec=pspec).run(
            BASE, n_trajectories=4, devices=2)
        assert_traces_match(ref, got)

    def test_explicit_one_device_mesh_matches_plain_path(self):
        """A genuine 1-device shard_map program (explicit mesh) agrees
        with the plain jit — and lives under its own cache key."""
        cache = PlanFnCache()
        mesh = fleet_mesh(1)
        ref = self._reference(cache)
        got = make_rollout(cache).run(BASE, n_trajectories=self.B,
                                      mesh=mesh)
        assert_traces_match(ref, got)
        assert mesh_signature(mesh) is not None

    @needs(8)
    def test_ragged_batch_padding_mask(self):
        """B = 100 on 8 devices: padded to 104 on the wire, masked back to
        100 in every statistic, padded rows flagged invalid."""
        B = 100
        cache = PlanFnCache()
        ref = make_rollout(cache).run(BASE, n_trajectories=B)
        got = make_rollout(cache).run(BASE, n_trajectories=B, devices=8)
        assert got.latency.shape[0] == 104       # ceil(100/8)*8
        assert got.valid is not None
        assert got.valid.sum() == B and got.valid[:B].all()
        assert got.n_trajectories == B
        assert_traces_match(ref, got)
        # a padded row is filler: asking for its frame stats is an error
        with pytest.raises(IndexError, match="padding"):
            got.frame_stats(trajectory=101)
        got.frame_stats(trajectory=0)            # real rows still work

    @needs(2)
    def test_host_streams_identical_before_padding(self):
        """Randomness is drawn for the REQUESTED B before padding: a
        ragged sharded run and the single-device run consume the same
        arrival stream (visible in the served counts)."""
        B = 3
        cache = PlanFnCache()
        ref = make_rollout(cache, seed=11).run(BASE, n_trajectories=B)
        got = make_rollout(cache, seed=11).run(BASE, n_trajectories=B,
                                               devices=2)
        np.testing.assert_array_equal(got.n_requests[got._valid()],
                                      ref.n_requests)


class TestShardedRetraces:
    """0-retrace assertions across repeated sharded runs, and the mesh-
    signature cache-key regression (the PlanFnCache bugfix)."""

    @needs(2)
    def test_zero_retraces_across_repeated_sharded_runs(self):
        cache = PlanFnCache()
        ro = make_rollout(cache)
        ro.run(BASE, n_trajectories=4, devices=2)
        traces = ro.trace_count
        assert traces >= 1
        for _ in range(3):
            ro.run(BASE, n_trajectories=4, devices=2)
        assert ro.trace_count == traces
        # a REBUILT rollout on the same mesh shares the compiled scan
        ro2 = make_rollout(cache, seed=9)
        ro2.run(BASE, n_trajectories=4, devices=2)
        assert ro2.trace_count == traces

    @needs(8)
    def test_mesh_signature_keys_never_collide(self):
        """The regression the bugfix satellite pins: a 1-device rollout
        followed by an 8-device rollout is 2 distinct cache entries — 2
        misses, 2 traces — and re-running EITHER adds hits, never traces.
        Before the mesh signature entered the key, the second program
        would have reused (and clobbered) the first entry."""
        cache = PlanFnCache()
        ro = make_rollout(cache)
        misses0 = cache.misses          # engine __init__ already missed
        ro.run(BASE, n_trajectories=8)              # 1-device program
        ro.run(BASE, n_trajectories=8, devices=8)   # 8-device program
        assert cache.misses - misses0 == 1   # the sharded key is new
        keys = [k for k in ro._cache_keys_used if k[0] == "rollout"]
        assert len(keys) == 2
        assert keys[0][1] is None                   # single-device
        assert keys[1][1] is not None and keys[1][1][0] == "mesh"
        assert cache.trace_count(keys) == 2
        hits0 = cache.hits
        ro.run(BASE, n_trajectories=8)
        ro.run(BASE, n_trajectories=8, devices=8)
        assert cache.trace_count(keys) == 2         # 0 retraces
        assert cache.hits > hits0

    def test_mesh_and_devices_are_mutually_exclusive(self):
        ro = make_rollout(PlanFnCache())
        with pytest.raises(ValueError, match="not both"):
            ro.run(BASE, mesh=fleet_mesh(1), devices=1)
        with pytest.raises(ValueError, match="available"):
            ro.run(BASE, devices=N_DEV + 1)


class TestShardedRuntimeIntegration:
    @needs(2)
    def test_replanner_horizon_lookahead_sharded(self):
        """The PeriodicReplanner's horizon lookahead rides the mesh: same
        feasibility pricing, 0 retraces across refreshes, ragged
        trajectory count (3 on 2 devices) masked transparently."""
        cache = PlanFnCache()
        engine = ScenarioEngine(CH, make_devices(U), MC, plan_cache=cache)
        ro = make_rollout(cache)
        rp = PeriodicReplanner(engine, ScenarioGenerator(BASE, seed=0),
                               period=2, n_scenarios=4, rollout=ro,
                               rollout_horizon=3, rollout_trajectories=3,
                               rollout_devices=2)
        for f in range(4):
            rp.tick(f)
        assert rp.refreshes == 2
        assert rp.retraces == 0
        assert rp.horizon is not None
        assert rp.horizon.n_trajectories == 3     # padding masked
        assert rp.horizon.latency.shape[0] == 4   # padded to the mesh
        assert 0.0 <= rp.horizon_feasibility <= 1.0
        assert rp.horizon_latency(50.0) > 0.0

    @needs(2)
    def test_constructor_default_mesh(self):
        """A FleetRollout built with mesh_devices= shards every run by
        default, and a per-run devices=1 override falls back to the
        single-device program."""
        cache = PlanFnCache()
        def sharded_by_default():
            return FleetRollout(CH, make_devices(U), MC, SPEC,
                                plan_cache=cache, seed=3, mesh_devices=2)

        got = sharded_by_default().run(BASE, n_trajectories=4)
        ref = make_rollout(cache).run(BASE, n_trajectories=4)
        assert_traces_match(ref, got)
        # per-run devices=1 override falls back to the single-device
        # program (fresh object: the host RNG is stateful per instance)
        over = sharded_by_default().run(BASE, n_trajectories=4, devices=1)
        assert_traces_match(ref, over)
