"""LLHR planner end-to-end + baselines + swarm + cost model tests."""
import numpy as np

from repro.configs.alexnet import ALEXNET
from repro.configs.lenet import LENET
from repro.configs.base import TRAIN_4K, DECODE_32K
from repro.configs.registry import get_arch
from repro.core import (HeuristicPlanner, LLHRPlanner, RandomPlanner, RadioChannel, SwarmSim, average_latency, cnn_cost, make_devices, model_flops, plan_pipeline, pipeline_efficiency)


class TestCostModel:
    def test_lenet_eq1_values(self):
        """Hand-checked eq. (1)/(2) values for LeNet."""
        mc = cnn_cost(LENET)
        by_name = {l.name: l for l in mc.layers}
        # conv1: 3 * 5^2 * 6 * 28^2
        assert by_name["conv1"].flops == 3 * 25 * 6 * 28 * 28
        # conv2: 6 * 5^2 * 16 * 10^2
        assert by_name["conv2"].flops == 6 * 25 * 16 * 100
        # fc1: 400 * 120 (eq. 2)
        assert by_name["fc1"].flops == 400 * 120
        assert by_name["fc3"].flops == 84 * 10

    def test_alexnet_scale(self):
        mc = cnn_cost(ALEXNET)
        assert 0.6e9 < mc.total_flops < 1.5e9        # ~1.1 GMAC
        assert 200e6 < mc.total_weight_bytes < 300e6  # ~250 MB fp32

    def test_memory_eq3(self):
        """m_j = W_j * b (eq. 3): fc1 has (400*120 + 120) fp32 weights."""
        mc = cnn_cost(LENET)
        fc1 = {l.name: l for l in mc.layers}["fc1"]
        assert fc1.weight_bytes == (400 * 120 + 120) * 4

    def test_arch_param_counts(self):
        for name, lo, hi in [("minicpm-2b", 2.4e9, 3.1e9),
                             ("gemma2-9b", 8.5e9, 10.5e9),
                             ("phi4-mini-3.8b", 3.5e9, 4.2e9),
                             ("olmoe-1b-7b", 6.0e9, 7.5e9)]:
            n = get_arch(name).n_params
            assert lo < n < hi, f"{name}: {n}"

    def test_model_flops_train_6nd(self):
        cfg = get_arch("phi4-mini-3.8b")
        mf = model_flops(cfg, TRAIN_4K)
        n = cfg.n_params
        assert np.isclose(mf, 6 * n * TRAIN_4K.tokens, rtol=1e-6)

    def test_moe_active_params_flops(self):
        cfg = get_arch("olmoe-1b-7b")
        mf = model_flops(cfg, DECODE_32K)
        # active ~1.3B << total 6.9B
        act = mf / (2 * DECODE_32K.global_batch)
        assert act < 2.5e9


class TestPlannerOrdering:
    def test_llhr_beats_baselines_lenet(self):
        ch = RadioChannel()
        mc = cnn_cost(LENET)
        devs = make_devices(6)
        llhr, _ = LLHRPlanner(ch, position_steps=80).plan(mc, devs,
                                                          [0, 1, 2])
        heur, _ = HeuristicPlanner(ch).plan(mc, make_devices(6), [0, 1, 2])
        rand, _ = RandomPlanner(ch).plan(mc, make_devices(6), [0, 1, 2])
        assert llhr.total_latency <= heur.total_latency + 1e-9
        assert llhr.total_latency <= rand.total_latency + 1e-9
        assert llhr.feasible

    def test_latency_increases_with_requests(self):
        """Fig. 5 trend: avg latency grows once caps bind."""
        ch = RadioChannel()
        mc = cnn_cost(ALEXNET)
        lat = []
        for rq in (2, 25):
            devs = make_devices(6)
            plan, _ = LLHRPlanner(ch, position_steps=60).plan(
                mc, devs, list(np.arange(rq) % 6))
            lat.append(plan.total_latency / rq)
        assert lat[1] >= lat[0] - 1e-9

    def test_latency_decreases_with_memory(self):
        """Fig. 3 trend (sweeping the eq. 11a cap)."""
        ch = RadioChannel()
        mc = cnn_cost(LENET)
        lats = []
        for mf in (2e-4, 1.0):
            devs = make_devices(6, mem_frac=mf)
            plan, _ = LLHRPlanner(ch, position_steps=60).plan(
                mc, devs, [0, 1, 2, 3])
            lats.append(plan.total_latency)
        assert lats[1] <= lats[0] + 1e-9

    def test_replan_on_failure_is_feasible(self):
        """The paper's delegation: drop a UAV, re-place, stay feasible."""
        ch = RadioChannel()
        mc = cnn_cost(LENET)
        devs = make_devices(6)
        pl = LLHRPlanner(ch, position_steps=60)
        plan, problems = pl.plan(mc, devs, [0, 1])
        plan2, _ = pl.replan_on_failure(plan, problems, dead=2)
        assert plan2.feasible
        assert plan2.positions.shape[0] == 5

    def test_breakdown_sums_to_total(self):
        ch = RadioChannel()
        mc = cnn_cost(LENET)
        devs = make_devices(5)
        pl = LLHRPlanner(ch, position_steps=60)
        plan, problems = pl.plan(mc, devs, [0, 1, 2])
        br = plan.latency_breakdown(problems)
        assert np.isclose(sum(br.values()), plan.total_latency, rtol=1e-6)


class TestSwarmSim:
    def test_sim_runs_with_failure_injection(self):
        ch = RadioChannel()
        mc = cnn_cost(LENET)
        devs = make_devices(5)
        sim = SwarmSim(mc, devs, LLHRPlanner(ch, position_steps=50),
                       requests_per_frame=2, failure_frame=1, failure_uav=1)
        stats = sim.run(frames=2)
        assert len(stats) == 2
        assert stats[1].replanned
        assert all(s.feasible for s in stats)
        assert np.isfinite(average_latency(stats))


class TestPipelinePlanner:
    def test_stage_plan_balanced(self):
        cfg = get_arch("gemma2-9b")
        sp = plan_pipeline(cfg, TRAIN_4K, n_stages=8, chips_per_stage=32)
        assert sp.n_stages == 8
        assert sum(sp.blocks_per_stage) == cfg.n_layers + 2  # embed+head
        eff = pipeline_efficiency(sp, 32)
        assert 0.5 < eff <= 1.0

    def test_stage_coords_adjacent(self):
        """P2 on the torus: consecutive stages land 1 hop apart."""
        from repro.core import ICIChannel
        cfg = get_arch("phi4-mini-3.8b")
        sp = plan_pipeline(cfg, TRAIN_4K, n_stages=6, chips_per_stage=32)
        ici = ICIChannel()
        for a, b in zip(sp.stage_coords[:-1], sp.stage_coords[1:]):
            assert ici.hops(a, b) == 1

    def test_elastic_replan_smaller_swarm(self):
        from repro.runtime.fault_tolerance import scale_elastic
        cfg = get_arch("qwen2-vl-2b")
        for n in (8, 7, 5):
            sp = scale_elastic(n, cfg, TRAIN_4K, chips_per_stage=32)
            assert sp.n_stages <= n
