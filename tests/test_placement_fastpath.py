"""Scan-based chain-DP fast path + compiled-plan cache regressions.

The scan DP (``_chain_dp_solve`` behind ``solve_chain_dp_batched``) must be
*indistinguishable* from both oracles:

* ``placement.solve_chain_dp``         — elementwise costs AND backtracked
                                         assignments, including tie-breaks
                                         (a outer, s0 inner, strict
                                         improvement), failed UAVs and
                                         infeasible links;
* the PR 1 unrolled tracer             — bit-identical assignments and
  (``solve_chain_dp_batched_unrolled``)  latencies on shared inputs.

The plan cache (``PlanFnCache``) must hand identical compiled plans to
every engine with the same signature and never retrace across frames — the
trace counters are bumped from inside the traced bodies, so they move only
on a real XLA retrace.
"""
import numpy as np

from repro.configs.lenet import LENET
from repro.core import (Device, PlacementProblem, RadioChannel, RadioParams,
                        cnn_cost, make_devices, solve_chain_dp,
                        solve_chain_dp_batched, solve_power,
                        solve_power_batched)
from repro.core.batch import (rate_matrix_batched,
                              solve_chain_dp_batched_unrolled)
from repro.core.positions import hex_init
from repro.runtime.scenario_engine import (ContingencyTable, PlanFnCache,
                                           ScenarioEngine, ScenarioGenerator)
from repro.runtime.serve_loop import PeriodicReplanner

RTOL = 1e-5
PARAMS = RadioParams()
CH = RadioChannel(PARAMS)


def random_rate(n_scenarios, n_uavs, seed=0, spread=120.0, active=None):
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0, spread, (n_scenarios, n_uavs, 2))
    dist = np.sqrt(((pos[:, :, None] - pos[:, None, :]) ** 2).sum(-1))
    sol = solve_power_batched(dist, PARAMS, active=active)
    rate = np.asarray(rate_matrix_batched(dist, sol.power, PARAMS,
                                          sol.link_feasible))
    return rate, dist, rng


def dp_args(compute, memory, act, input_bits, devs, rate, src):
    return (compute, memory, act, input_bits,
            np.array([d.mem_cap for d in devs]),
            np.array([d.compute_cap for d in devs]),
            np.array([d.throughput for d in devs]), rate, src)


def lenet_case(n_scenarios, n_uavs, seed, spread=120.0, mem_frac=1.0,
               active=None):
    mc = cnn_cost(LENET)
    compute = np.array([l.flops for l in mc.layers])
    memory = np.array([l.weight_bytes for l in mc.layers])
    act = np.array([l.act_bits for l in mc.layers])
    devs = make_devices(n_uavs, mem_frac=mem_frac)
    rate, dist, rng = random_rate(n_scenarios, n_uavs, seed=seed,
                                  spread=spread, active=active)
    src = rng.integers(0, n_uavs, n_scenarios)
    return (dp_args(compute, memory, act, mc.input_bits, devs, rate, src),
            devs, dist, mc)


class TestScanDP:
    def test_scalar_oracle_parity_costs_and_assignments(self):
        """Latency AND the backtracked assignment match the NumPy solver
        exactly (same tie-breaks) on randomized instances."""
        for seed in range(4):
            args, devs, dist, mc = lenet_case(12, 5, seed)
            assign, lat = solve_chain_dp_batched(*args)
            rate, src = args[7], args[8]
            for n in range(12):
                p = PlacementProblem(args[0], args[1], args[2], devs,
                                     rate[n], source=int(src[n]),
                                     input_bits=args[3])
                sol = solve_chain_dp(p)
                assert np.isfinite(lat[n]) == np.isfinite(sol.latency)
                if np.isfinite(sol.latency):
                    np.testing.assert_allclose(lat[n], sol.latency,
                                               rtol=RTOL)
                    assert tuple(assign[n]) == sol.assign

    def test_matches_unrolled_tracer_bitwise(self):
        """The scan rewrite is a pure reformulation of the PR 1 tracer:
        identical assignments, latencies equal to float32 rounding."""
        for seed, spread, mem_frac in ((0, 120.0, 1.0), (1, 60.0, 0.5),
                                       (2, 400.0, 1.0)):
            args, _, _, _ = lenet_case(10, 6, seed, spread=spread,
                                       mem_frac=mem_frac)
            a_new, l_new = solve_chain_dp_batched(*args)
            a_old, l_old = solve_chain_dp_batched_unrolled(*args)
            np.testing.assert_array_equal(a_new, a_old)
            np.testing.assert_allclose(l_new, l_old, rtol=1e-6)

    def test_failed_uav_excluded_and_matches_survivor_subproblem(self):
        n_scenarios, n_uavs = 6, 5
        active = np.ones((n_scenarios, n_uavs), dtype=bool)
        dead = [n % n_uavs for n in range(n_scenarios)]
        active[np.arange(n_scenarios), dead] = False
        mc = cnn_cost(LENET)
        compute = np.array([l.flops for l in mc.layers])
        memory = np.array([l.weight_bytes for l in mc.layers])
        act = np.array([l.act_bits for l in mc.layers])
        devs = make_devices(n_uavs)
        rate, dist, _ = random_rate(n_scenarios, n_uavs, seed=8,
                                    active=active)
        src = np.array([(d + 1) % n_uavs for d in dead])
        args = dp_args(compute, memory, act, mc.input_bits, devs, rate, src)
        assign, lat = solve_chain_dp_batched(*args, active=active)
        for n in range(n_scenarios):
            assert dead[n] not in assign[n]
            alive = np.flatnonzero(active[n])
            sub_rate = solve_power(dist[n][np.ix_(alive, alive)], CH) \
                .rate_matrix(CH, dist[n][np.ix_(alive, alive)])
            p = PlacementProblem(compute, memory, act,
                                 [devs[i] for i in alive], sub_rate,
                                 source=int(np.where(alive == src[n])[0][0]),
                                 input_bits=mc.input_bits)
            sol = solve_chain_dp(p)
            assert np.isfinite(lat[n]) == np.isfinite(sol.latency)
            if np.isfinite(sol.latency):
                np.testing.assert_allclose(lat[n], sol.latency, rtol=RTOL)
                # map survivor-space oracle assignment back to swarm ids
                assert tuple(assign[n]) == tuple(alive[j] for j in sol.assign)

    def test_infeasible_scenarios_are_minus_one(self):
        args, _, _, _ = lenet_case(6, 4, seed=7, spread=5000.0,
                                   mem_frac=1e-4)
        assign, lat = solve_chain_dp_batched(*args)
        assert not np.isfinite(lat).any()
        assert (assign == -1).all()

    def test_tie_break_parity_with_scalar_solver(self):
        """Engineered exact ties (identical devices, power-of-two costs, one
        shared rate) — the scan DP must pick the scalar solver's candidate:
        first (a, s0) in lexicographic order with strict improvement."""
        L, U = 6, 5
        compute = np.full(L, 1.0)
        memory = np.full(L, 1.0)
        act = np.full(L, 4.0)
        input_bits = 4.0
        devs = [Device(f"u{i}", mem_cap=2.0, compute_cap=64.0,
                       throughput=1.0) for i in range(U)]
        rate = np.full((U, U), 2.0)
        np.fill_diagonal(rate, np.inf)
        for src in range(3):
            args = dp_args(compute, memory, act, input_bits, devs,
                           np.broadcast_to(rate, (2, U, U)).copy(),
                           np.array([src, src]))
            assign, lat = solve_chain_dp_batched(*args)
            p = PlacementProblem(compute, memory, act, devs, rate,
                                 source=src, input_bits=input_bits)
            sol = solve_chain_dp(p)
            # all values are exactly representable: latencies must be EQUAL
            assert float(lat[0]) == sol.latency
            assert tuple(assign[0]) == sol.assign
            assert tuple(assign[1]) == sol.assign

    def test_large_instance_traces_and_solves(self):
        """U = L = 32 — intractable for the unrolled tracer — must trace,
        solve, and return a cost-consistent plan."""
        rng = np.random.default_rng(3)
        L, U, B = 32, 32, 4
        compute = np.abs(rng.normal(7e7, 3e7, L)) + 1e6
        memory = np.abs(rng.normal(2e6, 1e6, L)) + 1e4
        act = np.abs(rng.normal(6e5, 3e5, L)) + 1e4
        devs = make_devices(U)
        rate, _, _ = random_rate(B, U, seed=3, spread=250.0)
        src = rng.integers(0, U, B)
        args = dp_args(compute, memory, act, 1e6, devs, rate, src)
        assign, lat = solve_chain_dp_batched(*args)
        assert assign.shape == (B, L) and lat.shape == (B,)
        for n in range(B):
            if not np.isfinite(lat[n]):
                continue
            p = PlacementProblem(compute, memory, act, devs, rate[n],
                                 source=int(src[n]), input_bits=1e6)
            assert p.feasible(assign[n])
            np.testing.assert_allclose(p.latency(assign[n]), lat[n],
                                       rtol=RTOL)


class TestPlanCache:
    def _setup(self, n_uavs=5, cache=None):
        mc = cnn_cost(LENET)
        devs = make_devices(n_uavs)
        cache = cache if cache is not None else PlanFnCache()
        engine = ScenarioEngine(CH, devs, mc, plan_cache=cache)
        return engine, hex_init(n_uavs, 40.0), cache

    def test_cache_shared_across_engines_identical_plans(self):
        engine1, base, cache = self._setup()
        assert cache.misses == 1 and cache.hits == 0    # ONE fused solve fn
        engine2, _, _ = self._setup(cache=cache)
        assert cache.misses == 1 and cache.hits == 1    # same signature
        batch = ScenarioGenerator(base, pos_sigma_m=2.0, seed=0).draw(8)
        p1 = engine1.plan_batch(batch)
        p2 = engine2.plan_batch(batch)
        np.testing.assert_array_equal(p1.assign, p2.assign)
        np.testing.assert_allclose(p1.latency, p2.latency)
        np.testing.assert_allclose(p1.power, p2.power)
        # ONE compile served both engines
        assert engine1.trace_count == 1
        assert engine2.trace_count == 1

    def test_plan_batch_never_retraces_at_fixed_shape(self):
        engine, base, _ = self._setup()
        gen = ScenarioGenerator(base, pos_sigma_m=2.0, seed=1)
        first = engine.plan_batch(gen.draw(8))
        traces = engine.trace_count
        assert traces > 0
        for _ in range(5):
            engine.plan_batch(gen.draw(8))
        assert engine.trace_count == traces      # zero retraces
        again = engine.plan_batch(first.scenarios)
        np.testing.assert_array_equal(again.assign, first.assign)
        np.testing.assert_allclose(again.latency, first.latency)

    def test_new_batch_shape_retraces_once(self):
        engine, base, _ = self._setup()
        gen = ScenarioGenerator(base, pos_sigma_m=2.0, seed=2)
        engine.plan_batch(gen.draw(8))
        t8 = engine.trace_count
        engine.plan_batch(gen.draw(16))          # new shape: one retrace
        t16 = engine.trace_count
        assert t16 > t8
        engine.plan_batch(gen.draw(16))
        engine.plan_batch(gen.draw(8))           # both shapes now cached
        assert engine.trace_count == t16

    def test_periodic_replanner_zero_retraces(self):
        engine, base, _ = self._setup()
        gen = ScenarioGenerator(base, pos_sigma_m=1.0, seed=0)
        rp = PeriodicReplanner(engine, gen, period=3, n_scenarios=8)
        for f in range(12):
            rp.tick(f)
        assert rp.refreshes == 4
        assert rp.retraces == 0
        assert rp.last_refresh_s > 0.0

    def test_contingency_refresh_reuses_compiled_plan(self):
        engine, base, _ = self._setup()
        table = ContingencyTable(engine, base, source=0)
        traces = engine.trace_count
        nominal = table.plans[None].assign
        table.refresh(base + 0.25, source=0)
        assert engine.trace_count == traces
        assert len(table.plans[None].assign) == len(nominal)
