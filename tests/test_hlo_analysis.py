"""The loop-aware HLO profiler, tested against graphs with known costs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import parse_hlo, profile
from repro.launch.roofline import Roofline


def compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


class TestDotFlops:
    def test_single_matmul_exact(self):
        a = jnp.zeros((128, 256), jnp.float32)
        b = jnp.zeros((256, 512), jnp.float32)
        text = compiled_text(lambda a, b: a @ b, a, b)
        prof = profile(text)
        assert prof.dot_flops == pytest.approx(2 * 128 * 256 * 512, rel=.01)

    def test_scan_multiplies_by_trip_count(self):
        """cost_analysis counts a while body once; the profiler must
        multiply by the trip count."""
        w = jnp.zeros((64, 64), jnp.float32)
        x = jnp.zeros((8, 64), jnp.float32)

        def fn(w, x):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, None, length=10)
            return y

        prof = profile(compiled_text(fn, w, x))
        expect = 10 * 2 * 8 * 64 * 64
        assert prof.dot_flops == pytest.approx(expect, rel=0.05)
        assert any(t == 10 for _, t in prof.loops)

    def test_nested_scans_multiply(self):
        w = jnp.zeros((32, 32), jnp.float32)
        x = jnp.zeros((4, 32), jnp.float32)

        def fn(w, x):
            def outer(c, _):
                def inner(ci, _):
                    return ci @ w, None
                c2, _ = jax.lax.scan(inner, c, None, length=5)
                return c2, None
            y, _ = jax.lax.scan(outer, x, None, length=3)
            return y

        prof = profile(compiled_text(fn, w, x))
        expect = 3 * 5 * 2 * 4 * 32 * 32
        assert prof.dot_flops == pytest.approx(expect, rel=0.05)


class TestTraffic:
    def test_elementwise_traffic_scale(self):
        x = jnp.zeros((1024, 1024), jnp.float32)
        prof = profile(compiled_text(lambda x: x * 2.0 + 1.0, x))
        # one read + one write of 4MB, allow fusion slack
        assert 4e6 < prof.traffic_bytes < 5e7


class TestRooflineTerms:
    def test_bottleneck_selection(self):
        r = Roofline(flops_dev=197e12, bytes_dev=0, coll_bytes_dev=0,
                     pod_bytes_dev=0, n_chips=1, model_flops=197e12)
        assert r.bottleneck == "compute"
        assert r.compute_s == pytest.approx(1.0)
        assert r.roofline_fraction == pytest.approx(1.0)

    def test_pod_bytes_use_dcn_bandwidth(self):
        r = Roofline(flops_dev=0, bytes_dev=0, coll_bytes_dev=6.25e9,
                     pod_bytes_dev=6.25e9, n_chips=512, model_flops=1.0)
        assert r.collective_s == pytest.approx(1.0)   # all bytes on DCN

    def test_useful_ratio(self):
        r = Roofline(flops_dev=2.0, bytes_dev=0, coll_bytes_dev=0,
                     pod_bytes_dev=0, n_chips=10, model_flops=10.0)
        assert r.useful_ratio == pytest.approx(0.5)


class TestParser:
    def test_parse_computations(self):
        x = jnp.zeros((8, 8), jnp.float32)
        text = compiled_text(lambda x: jnp.tanh(x @ x), x)
        comps = parse_hlo(text)
        assert comps
        assert any(len(c.instrs) > 0 for c in comps.values())
