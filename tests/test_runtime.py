"""Runtime tests: training convergence, checkpoint durability, fault
tolerance / straggler mitigation, serving batcher, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ServeConfig, TrainConfig
from repro.configs.registry import get_arch
from repro.core.placement import Device
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM, lm_data
from repro.models import build_model
from repro.runtime import checkpoint as ckpt
from repro.runtime.fault_tolerance import FaultTolerantRunner
from repro.runtime.serve_loop import ContinuousBatcher, Request
from repro.runtime.train_loop import init_state, make_train_step, train_loop

KEY = jax.random.PRNGKey(0)


def tiny_cfg():
    return get_arch("phi4-mini-3.8b").reduced()


class TestTraining:
    def test_loss_decreases(self):
        cfg = tiny_cfg()
        model = build_model(cfg)
        tcfg = TrainConfig(steps=30, lr=3e-3, warmup_steps=5,
                           schedule="wsd")
        data = lm_data(cfg, batch=8, seq_len=32, prefetch=0)
        _, hist = train_loop(model, cfg, tcfg, iter(data))
        first = np.mean([h["loss"] for h in hist[:5]])
        last = np.mean([h["loss"] for h in hist[-5:]])
        assert last < first - 0.2, f"{first} -> {last}"

    def test_grad_accumulation_matches_full_batch(self):
        cfg = tiny_cfg()
        model = build_model(cfg)
        data = next(iter(lm_data(cfg, batch=8, seq_len=16, prefetch=0)))
        batch = {k: jnp.asarray(v) for k, v in data.items()}
        s1 = init_state(model, KEY, TrainConfig(microbatches=1))
        s2 = init_state(model, KEY, TrainConfig(microbatches=4))
        st1, m1 = make_train_step(model, cfg, TrainConfig(
            microbatches=1))(s1, batch)
        st2, m2 = make_train_step(model, cfg, TrainConfig(
            microbatches=4))(s2, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-4)
        g1 = jax.tree.leaves(st1["params"])[0]
        g2 = jax.tree.leaves(st2["params"])[0]
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   atol=1e-4)

    def test_grad_compress_error_feedback(self):
        """Compressed training still converges (error feedback works)."""
        cfg = tiny_cfg()
        model = build_model(cfg)
        tcfg = TrainConfig(steps=25, lr=3e-3, warmup_steps=5,
                           grad_compress=True)
        data = lm_data(cfg, batch=8, seq_len=32, prefetch=0)
        _, hist = train_loop(model, cfg, tcfg, iter(data))
        assert hist[-1]["loss"] < hist[0]["loss"]

    def test_wsd_schedule_shape(self):
        from repro.optim.schedules import wsd
        lr = [float(wsd(s, peak_lr=1.0, total_steps=100, warmup_steps=10,
                        decay_frac=0.2)) for s in range(100)]
        assert lr[0] < 0.2                       # warmup start
        assert abs(lr[50] - 1.0) < 1e-6          # stable plateau
        assert lr[99] < 0.2                      # decayed


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
                "b": {"c": np.ones(5, np.int32),
                      "step": np.asarray(7)}}
        ckpt.save(str(tmp_path), 3, tree)
        assert ckpt.latest_step(str(tmp_path)) == 3
        got = ckpt.restore(str(tmp_path), 3, tree)
        np.testing.assert_array_equal(got["a"], tree["a"])
        np.testing.assert_array_equal(got["b"]["c"], tree["b"]["c"])

    def test_torn_checkpoint_ignored(self, tmp_path):
        """A step dir without COMMIT never becomes 'latest' (crash mid-
        write safety)."""
        tree = {"x": np.ones(3)}
        ckpt.save(str(tmp_path), 1, tree)
        torn = os.path.join(str(tmp_path), "step_00000002")
        os.makedirs(torn)
        with open(os.path.join(torn, "manifest.json"), "w") as f:
            f.write("{}")
        assert ckpt.latest_step(str(tmp_path)) == 1

    def test_corruption_detected(self, tmp_path):
        tree = {"x": np.ones(8, np.float32)}
        path = ckpt.save(str(tmp_path), 1, tree)
        leaf = os.path.join(path, "leaf_0.npy")
        arr = np.load(leaf)
        arr[0] = 42.0
        np.save(leaf, arr)
        with pytest.raises(IOError):
            ckpt.restore(str(tmp_path), 1, tree)

    def test_async_checkpointer(self, tmp_path):
        tree = {"x": np.arange(6, dtype=np.float32)}
        ac = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
        for step in (1, 2, 3):
            ac.save(step, jax.tree.map(lambda a: a * step, tree))
        ac.close()
        assert ckpt.latest_step(str(tmp_path)) == 3
        got = ckpt.restore(str(tmp_path), 3, tree)
        np.testing.assert_array_equal(got["x"], tree["x"] * 3)
        assert ckpt.latest_step(str(tmp_path)) == 3  # pruned to keep=2

    def test_train_resume_from_checkpoint(self, tmp_path):
        cfg = tiny_cfg()
        model = build_model(cfg)
        tcfg = TrainConfig(steps=6, lr=1e-3)
        data = lm_data(cfg, batch=4, seq_len=16, prefetch=0)
        state, _ = train_loop(model, cfg, tcfg, iter(data))
        ckpt.save(str(tmp_path), 6, state)
        like = init_state(model, KEY, tcfg)
        restored = ckpt.restore(str(tmp_path), 6, like)
        assert int(restored["opt"]["step"]) == 6
        # resume two more steps
        tcfg2 = TrainConfig(steps=8, lr=1e-3)
        state2, hist = train_loop(model, cfg, tcfg2, iter(data),
                                  state=jax.tree.map(jnp.asarray, restored))
        assert len(hist) == 2


class TestFaultTolerance:
    def _runner(self, tmp_path, n=6):
        devices = [Device(f"d{i}", 1e9, 1e12, 5e8) for i in range(n)]
        calls = []

        def replan(devs):
            calls.append(len(devs))
            return {"n": len(devs)}

        return FaultTolerantRunner(devices, replan, str(tmp_path)), calls

    def test_failure_triggers_replan(self, tmp_path):
        runner, calls = self._runner(tmp_path)
        plan = runner.on_failure(["d2"])
        assert plan["n"] == 5
        assert runner.state.generation == 1
        assert runner.events[-1]["kind"] == "failure"

    def test_heartbeat_timeout_detection(self, tmp_path):
        runner, _ = self._runner(tmp_path)
        now = 1000.0
        for d in runner.health.devices.values():
            runner.health.heartbeat(d.name, 0.1, now=now)
        runner.health.heartbeat("d0", 0.1, now=now + 100)
        dead, slow = runner.health.scan(now=now + 100)
        assert set(dead) == {f"d{i}" for i in range(1, 6)}

    def test_straggler_demoted_and_replanned(self, tmp_path):
        runner, calls = self._runner(tmp_path)
        now = 0.0
        for i, d in enumerate(runner.health.devices.values()):
            for _ in range(5):
                runner.health.heartbeat(d.name, 2.0 if d.name == "d3"
                                        else 0.1, now=now)
        plan = runner.tick(now=now + 1)
        assert plan is not None
        assert runner.events[-1]["kind"] == "straggler"
        d3 = [d for d in runner.state.devices if d.name == "d3"][0]
        assert d3.throughput < 5e8

    def test_all_dead_raises(self, tmp_path):
        runner, _ = self._runner(tmp_path, n=2)
        with pytest.raises(RuntimeError):
            runner.on_failure(["d0", "d1"])


class TestServing:
    def test_continuous_batcher_completes_requests(self):
        cfg = tiny_cfg()
        model = build_model(cfg)
        params = model.init(KEY)
        scfg = ServeConfig(max_batch=2, max_seq=64, decode_steps=4)
        batcher = ContinuousBatcher(model, cfg, scfg, params)
        for rid in range(3):
            batcher.submit(Request(rid, prompt=[2, 3, 4 + rid], max_new=6))
        done = batcher.run(max_steps=200)
        assert len(done) == 3
        for r in done:
            assert len(r.out) >= 1
            assert all(0 <= t < cfg.vocab_size for t in r.out)


class TestData:
    def test_synthetic_structure_learnable(self):
        d = SyntheticLM(DataConfig(batch=4, seq_len=64, vocab_size=97,
                                   structure=1.0))
        b = d.batch()
        # fully structured: labels follow the affine grammar
        nxt = (d.a * b["tokens"] + d.c) % 97
        assert np.mean(nxt == b["labels"]) == 1.0

    def test_hosts_get_different_streams(self):
        b0 = SyntheticLM(DataConfig(host_id=0, n_hosts=2)).batch()
        b1 = SyntheticLM(DataConfig(host_id=1, n_hosts=2)).batch()
        assert not np.array_equal(b0["tokens"], b1["tokens"])

    def test_prefetcher_preserves_order(self):
        it = Prefetcher(iter(range(10)), depth=3)
        assert list(it) == list(range(10))
