"""Quickstart: plan a UAV swarm with LLHR and run the partitioned CNN.

    PYTHONPATH=src python examples/quickstart.py

1. Builds the paper's LeNet cost model (eq. 1-3).
2. Runs the three LLHR stages: P2 positions -> P1 powers -> P3 placement.
3. Executes LeNet partitioned exactly as placed and checks the prediction
   is identical to the monolithic model.
"""
import jax
import jax.numpy as jnp

from repro.configs.lenet import LENET
from repro.core import LLHRPlanner, RadioChannel, cnn_cost, make_devices
from repro.models.cnn import distributed_forward, forward, init_cnn


def main() -> None:
    # --- the paper's model + swarm -------------------------------------
    model_cost = cnn_cost(LENET)
    devices = make_devices(5, mem_frac=2e-4)   # 5 UAVs, ~215 KB weight
    # budget each: LeNet (242 KB of weights/request) MUST be distributed
    channel = RadioChannel()           # Section IV constants

    print("LeNet placeable layers:")
    for l in model_cost.layers:
        print(f"  {l.name:8s} c_j={l.flops:10.0f} MACs   "
              f"m_j={l.weight_bytes:9.0f} B   K_j={l.act_bits:9.0f} bits")

    # --- LLHR: P2 -> P1 -> P3 -------------------------------------------
    planner = LLHRPlanner(channel, position_steps=200)
    plan, problems = planner.plan(model_cost, devices, requests=[0, 1])

    print("\nOptimal UAV positions (P2):")
    for i, (x, y) in enumerate(plan.positions):
        print(f"  uav{i}: ({x:7.1f}, {y:7.1f}) m   "
              f"P_i = {plan.power.power[i] * 1e3:6.2f} mW")
    print(f"Total transmit power (P1): {plan.total_power * 1e3:.2f} mW")
    for r, sol in enumerate(plan.placements):
        print(f"request {r}: layers -> UAVs {sol.assign}   "
              f"latency {sol.latency * 1e3:.2f} ms  [{sol.solver}]")
    print("breakdown:", {k: f"{v * 1e3:.2f} ms" for k, v in
                         plan.latency_breakdown(problems).items()})

    # --- execute the placement ------------------------------------------
    params = init_cnn(jax.random.PRNGKey(0), LENET)
    img = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 3))
    y_mono = forward(LENET, params, img)
    y_dist, hops = distributed_forward(LENET, params, img,
                                       plan.placements[0].assign)
    same = bool(jnp.all(y_mono == y_dist))
    print(f"\npartitioned inference == monolithic: {same} "
          f"({hops} inter-UAV transfers)")
    print("predicted class:", int(jnp.argmax(y_dist[0])))

    # --- failure delegation ----------------------------------------------
    victim = plan.placements[0].assign[0]
    plan2, _ = planner.replan_on_failure(plan, problems, dead=victim)
    print(f"\nUAV {victim} failed -> re-planned on survivors: "
          f"feasible={plan2.feasible}, "
          f"latency {plan2.total_latency * 1e3:.2f} ms")


if __name__ == "__main__":
    main()
