"""Serving driver: continuous batching over a small LM, with the LLHR
planner choosing the stage placement the way the paper places CNN layers
on UAVs (here: transformer blocks on pipeline stage groups).

    PYTHONPATH=src python examples/serve_swarm.py
"""
import time

import jax
import numpy as np

from repro.configs.base import (ArchConfig, AttentionConfig, DECODE_32K,
                                ServeConfig)
from repro.core import plan_pipeline
from repro.models import build_model
from repro.runtime.serve_loop import ContinuousBatcher, Request


def main() -> None:
    cfg = ArchConfig(
        name="serve-lm", family="dense", n_layers=4, d_model=256,
        d_ff=768, vocab_size=2048,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=64),
        tie_embeddings=True, remat="none", dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name} ({cfg.n_params / 1e6:.1f}M params)")

    # LLHR placement of the decode stack (the paper's P3 on serve costs)
    plan = plan_pipeline(cfg, DECODE_32K, n_stages=2, chips_per_stage=8)
    print(f"LLHR decode placement: blocks/stage={plan.blocks_per_stage} "
          f"period={plan.bottleneck_s * 1e6:.1f}us "
          f"coords={plan.stage_coords}")

    scfg = ServeConfig(max_batch=4, max_seq=96)
    batcher = ContinuousBatcher(model, cfg, scfg, params)
    rng = np.random.default_rng(0)
    t0 = time.time()
    n_req = 8
    for rid in range(n_req):
        prompt = [int(x) for x in rng.integers(2, cfg.vocab_size,
                                               size=rng.integers(4, 12))]
        batcher.submit(Request(rid, prompt=prompt, max_new=12))
    done = batcher.run(max_steps=2000)
    dt = time.time() - t0
    tokens = sum(len(r.out) for r in done)
    print(f"completed {len(done)}/{n_req} requests, {tokens} tokens "
          f"in {dt:.1f}s ({tokens / dt:.1f} tok/s on 1 CPU core)")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[:4]={r.prompt[:4]} -> "
              f"out[:8]={r.out[:8]}")
    assert len(done) == n_req


if __name__ == "__main__":
    main()
