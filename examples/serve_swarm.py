"""Serving driver: continuous batching over a small LM, with the LLHR
planner choosing the stage placement the way the paper places CNN layers
on UAVs (here: transformer blocks on pipeline stage groups).

    PYTHONPATH=src python examples/serve_swarm.py

``--chaos`` instead drives the LIVE recovery path: a one-crash
``FaultSchedule`` feeds heartbeats into the health tracker while a
``ReplanController`` watches the SLO — the crashed UAV must time out, the
armed contingency table must answer, and the loop must end recovered.

    PYTHONPATH=src python examples/serve_swarm.py --chaos

``--stream`` drives the deadline-aware streaming gateway: an open-loop
arrival stream (plus an injected flood and a device stall past the retry
cap) flows through bounded admission into the fused rollout — the demo
must shed deterministically, degrade, and recover.

    PYTHONPATH=src python examples/serve_swarm.py --stream
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import (ArchConfig, AttentionConfig, DECODE_32K,
                                ServeConfig)
from repro.core import plan_pipeline
from repro.models import build_model
from repro.runtime.serve_loop import ContinuousBatcher, Request


def main_lm() -> None:
    cfg = ArchConfig(
        name="serve-lm", family="dense", n_layers=4, d_model=256,
        d_ff=768, vocab_size=2048,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=64),
        tie_embeddings=True, remat="none", dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name} ({cfg.n_params / 1e6:.1f}M params)")

    # LLHR placement of the decode stack (the paper's P3 on serve costs)
    plan = plan_pipeline(cfg, DECODE_32K, n_stages=2, chips_per_stage=8)
    print(f"LLHR decode placement: blocks/stage={plan.blocks_per_stage} "
          f"period={plan.bottleneck_s * 1e6:.1f}us "
          f"coords={plan.stage_coords}")

    scfg = ServeConfig(max_batch=4, max_seq=96)
    batcher = ContinuousBatcher(model, cfg, scfg, params)
    rng = np.random.default_rng(0)
    t0 = time.time()
    n_req = 8
    for rid in range(n_req):
        prompt = [int(x) for x in rng.integers(2, cfg.vocab_size,
                                               size=rng.integers(4, 12))]
        batcher.submit(Request(rid, prompt=prompt, max_new=12))
    done = batcher.run(max_steps=2000)
    dt = time.time() - t0
    tokens = sum(len(r.out) for r in done)
    print(f"completed {len(done)}/{n_req} requests, {tokens} tokens "
          f"in {dt:.1f}s ({tokens / dt:.1f} tok/s on 1 CPU core)")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[:4]={r.prompt[:4]} -> "
              f"out[:8]={r.out[:8]}")
    assert len(done) == n_req


def main_chaos() -> None:
    """One-crash chaos schedule through the live serve-loop recovery
    path: schedule -> heartbeats -> timeout -> contingency delegation."""
    from repro.configs.lenet import LENET
    from repro.core import (RadioChannel, RadioParams, RolloutSpec,
                            cnn_cost, make_devices)
    from repro.core.positions import hex_init
    from repro.runtime.chaos import ChaosHostDriver, FaultSchedule
    from repro.runtime.fault_tolerance import (FaultTolerantRunner,
                                               HealthTracker)
    from repro.runtime.fleet_rollout import FleetRollout
    from repro.runtime.scenario_engine import (ContingencyTable, PlanFnCache,
                                               ScenarioEngine,
                                               ScenarioGenerator)
    from repro.runtime.serve_loop import (PeriodicReplanner, ReplanController,
                                          ServiceLevelObjective)

    U, T = 5, 12
    cache = PlanFnCache()
    devs = make_devices(U, mem_frac=2e-4)        # forced chain split
    mc = cnn_cost(LENET)
    ch = RadioChannel(RadioParams())
    base = hex_init(U, 40.0, jitter=0.5, seed=1)
    names = [d.name for d in devs]

    engine = ScenarioEngine(ch, devs, mc, plan_cache=cache)
    table = ContingencyTable(engine, base, source=0)
    tracker = HealthTracker(names, timeout_s=2.5, now=0.0)
    runner = FaultTolerantRunner(devs, lambda d: {"n": len(d)}, ".",
                                 contingency=table, health=tracker)
    rollout = FleetRollout(ch, devs, mc, RolloutSpec(frames=4),
                           plan_cache=cache, seed=0)
    replanner = PeriodicReplanner(
        engine, ScenarioGenerator(base, pos_sigma_m=1.0, seed=0),
        period=4, n_scenarios=4, rollout=rollout, rollout_horizon=4,
        rollout_trajectories=4)
    controller = ReplanController(
        replanner, ServiceLevelObjective(min_horizon_feasibility=0.25),
        runner=runner)

    schedule = FaultSchedule(U, T, seed=0).crash(frame=4, uav=2)
    driver = ChaosHostDriver(schedule, tracker, base, frame_s=1.0)
    print(f"chaos: {U} UAVs, crash of uav2 at frame 4, "
          f"timeout {tracker.timeout}s")
    for t in range(T):
        now = driver.play_frame(t)
        controller.step(t, now=now)
    m = controller.metrics()
    failures = [e for e in runner.events if e["kind"] == "failure"]
    print(f"events: {[(e['kind'], e.get('dead')) for e in runner.events]}")
    print(f"recovered: mode={controller.mode} unrecovered="
          f"{m['n_unrecovered']} mttr={m['mttr_frames']:.1f} frames "
          f"churn={m['generation_churn']} retraces={replanner.retraces}")
    assert failures and failures[0]["precomputed"], \
        "the armed contingency table must answer the crash"
    assert [d.name for d in runner.state.devices] == \
        [n for n in names if n != "uav2"]
    assert controller.mode == controller.NOMINAL and \
        m["n_unrecovered"] == 0, "loop must end recovered"
    assert replanner.retraces == 0
    print("chaos run recovered through the contingency path")


def main_stream() -> None:
    """Live streaming demo: an open-loop arrival stream floods the
    deadline-aware gateway while an injected device stall burns through
    the retry cap — the gateway must shed deterministically, fall into
    degraded admission, then recover on the next healthy window."""
    from repro.configs.lenet import LENET
    from repro.core import (RadioChannel, RadioParams, RolloutSpec,
                            cnn_cost, make_devices)
    from repro.core.positions import hex_init
    from repro.runtime.chaos import FaultSchedule
    from repro.runtime.fleet_rollout import FleetRollout
    from repro.runtime.gateway import (GatewayConfig, LoadGenerator,
                                       StreamingGateway)
    from repro.runtime.scenario_engine import PlanFnCache

    U, T, W = 4, 4, 5                     # UAVs, frames/window, windows
    cache = PlanFnCache()
    devs = make_devices(U, mem_frac=2e-4)        # forced chain split
    base = hex_init(U, 40.0, jitter=0.5, seed=1)
    rollout = FleetRollout(
        RadioChannel(RadioParams()), devs, cnn_cost(LENET),
        RolloutSpec(frames=T, requests_per_frame=3, recovery_prob=0.5),
        plan_cache=cache, seed=0)

    # window 1 stalls past the retry cap (-> degraded admission); windows
    # 2-3 offer a 3x arrival flood the bounded queue must shed through
    schedule = (FaultSchedule(U, T * W, seed=0)
                .device_stall(T, attempts=3)
                .arrival_flood(2 * T, 3.0, frames=2 * T))
    gw = StreamingGateway(
        rollout, base,
        GatewayConfig(window_frames=T, frame_s=1.0, queue_capacity=16,
                      frame_capacity=3, retry_base_backoff_s=0.001,
                      max_attempts=2),
        schedule=schedule, seed=0)
    gen = LoadGenerator(U, kind="poisson", rate=2.0, deadline_s=6.0,
                        seed=3, priorities=(0, 1),
                        priority_weights=(0.3, 0.7))
    print(f"stream: {U} UAVs, {W} windows x {T} frames, stall at window "
          f"1 (cap 2 attempts), 3x flood from frame {2 * T}")
    for w in range(W):
        rep = gw.serve(gen, n_windows=1, drain=(w == W - 1))
        print(f"  window {w}: submitted={rep['submitted']} "
              f"served={rep['served']} shed={rep['shed']} "
              f"backpressure={gw.backpressure:.2f} "
              f"degraded={gw.degraded}")
    rep = gw.report()
    gw.close()
    print(f"stream: hit_rate={rep['deadline_hit_rate']:.3f} "
          f"p99={rep['latency_p99_s']:.1f}s retries={rep['retries']} "
          f"device_failures={rep['device_failures']} "
          f"windows_failed={rep['windows_failed']}")
    assert rep["device_failures"] == 1, "the stalled window must exhaust"
    assert not gw.degraded, "a healthy window must clear degraded mode"
    assert rep["served"] > 0 and rep["deadline_hit_rate"] == 1.0
    assert rep["served"] + rep["shed_total"] == rep["submitted"]
    print("stream demo recovered: flood shed at admission, stall shed at "
          "the retry cap, healthy windows served on time")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--chaos", action="store_true",
                    help="run the one-crash chaos recovery demo instead "
                         "of the LM serving demo")
    ap.add_argument("--stream", action="store_true",
                    help="run the streaming-gateway flood/stall recovery "
                         "demo instead of the LM serving demo")
    args = ap.parse_args()
    if args.chaos:
        main_chaos()
    elif args.stream:
        main_stream()
    else:
        main_lm()


if __name__ == "__main__":
    main()
