"""The paper's evaluation, end to end: time-framed swarm simulation with
all three planners, request scaling, failure injection and the Fig. 2-5
quantities printed as a table.

The LLHR rows run on the device-side fleet rollout (the whole frame loop
is ONE jit call — see docs/fleet_rollout.md); the baselines go through the
legacy host loop via the uniform SwarmPlanner protocol.  Every row reports
its feasibility rate so infeasible frames can't hide inside the mean.

    PYTHONPATH=src python examples/uav_swarm_sim.py [--frames 3]
"""
import argparse


from repro.configs.alexnet import ALEXNET
from repro.configs.lenet import LENET
from repro.core import (HeuristicPlanner, LLHRPlanner, RandomPlanner,
                        RadioChannel, RadioParams, SwarmSim,
                        average_power, cnn_cost, latency_summary,
                        make_devices, solve_chain_dp)


def llhr(ch, steps):
    """Chain-DP-placement LLHR planner — the solver the fused rollout
    implements, so SwarmSim's auto backend runs the whole frame loop in
    one device call."""
    return LLHRPlanner(ch, placement_solver=solve_chain_dp,
                       position_steps=steps)


def run(model_name, cfg, planner_name, planner, frames, fail=False):
    sim = SwarmSim(cnn_cost(cfg), make_devices(6), planner,
                   requests_per_frame=4,
                   failure_frame=1 if fail else -1, failure_uav=2)
    stats = sim.run(frames=frames)
    s = latency_summary(stats)
    pw = average_power(stats)
    flag = " (+failure@1)" if fail else ""
    print(f"  {model_name:8s} {planner_name:10s} avg latency "
          f"{s.mean_latency:8.4f} s   avg power {pw * 1e3:7.2f} mW   "
          f"feasible {100 * s.feasibility_rate:3.0f}%{flag}")
    return s.mean_latency


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=3)
    args = ap.parse_args()
    ch = RadioChannel(RadioParams())

    print("=== swarm simulation:", args.frames, "frames, 6 UAVs, "
          "4 requests/frame ===")
    for model_name, cfg in (("lenet", LENET), ("alexnet", ALEXNET)):
        lat = run(model_name, cfg, "LLHR", llhr(ch, 80), args.frames)
        heur = run(model_name, cfg, "heuristic", HeuristicPlanner(ch),
                   args.frames)
        rand = run(model_name, cfg, "random", RandomPlanner(ch),
                   args.frames)
        assert lat <= heur + 1e-9 and lat <= rand + 1e-9, \
            "LLHR must dominate (Fig. 5)"
    print("\n=== failure delegation (the paper's Section II semantics) ===")
    run("lenet", LENET, "LLHR", llhr(ch, 80), args.frames, fail=True)
    print("\nall orderings match the paper: LLHR <= heuristic <= random")


if __name__ == "__main__":
    main()
