"""End-to-end training driver: WSD schedule, grad accumulation, async
checkpointing, failure-recovery restart, LLHR pipeline plan printout.

    PYTHONPATH=src python examples/train_lm.py                 # ~12M params
    PYTHONPATH=src python examples/train_lm.py --full          # ~100M params
    PYTHONPATH=src python examples/train_lm.py --steps 300

The default config is CPU-sized so the loss curve is demonstrable in
minutes; --full selects the ~100M-parameter model (same code path, the
one a TPU slice would train; on this CPU container budget ~30 s/step).
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import (ArchConfig, AttentionConfig, TRAIN_4K,
                                TrainConfig)
from repro.core import plan_pipeline
from repro.data.pipeline import lm_data
from repro.models import build_model
from repro.runtime import checkpoint as ckpt
from repro.runtime.train_loop import init_state, train_loop


def nano_config(full: bool) -> ArchConfig:
    if full:     # ~100M params (llama-like)
        return ArchConfig(
            name="lm-100m", family="dense", n_layers=12, d_model=768,
            d_ff=2048, vocab_size=32000,
            attention=AttentionConfig(n_heads=12, n_kv_heads=4,
                                      head_dim=64),
            tie_embeddings=True, remat="none", dtype="float32")
    return ArchConfig(
        name="lm-12m", family="dense", n_layers=6, d_model=384,
        d_ff=1024, vocab_size=4096,
        attention=AttentionConfig(n_heads=6, n_kv_heads=2, head_dim=64),
        tie_embeddings=True, remat="none", dtype="float32")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--simulate-failure", action="store_true",
                    help="kill training at 60%% and restart from the "
                    "latest committed checkpoint")
    args = ap.parse_args()

    cfg = nano_config(args.full)
    model = build_model(cfg)
    print(f"arch {cfg.name}: {cfg.n_params / 1e6:.1f}M params")

    # LLHR view of this model as a pipeline (what a pod deployment uses)
    plan = plan_pipeline(cfg, TRAIN_4K, n_stages=4, chips_per_stage=64)
    print(f"LLHR 4-stage pipeline plan: blocks/stage="
          f"{plan.blocks_per_stage} bottleneck={plan.bottleneck_s * 1e3:.1f}"
          f"ms coords={plan.stage_coords}")

    tcfg = TrainConfig(steps=args.steps, lr=1e-3, warmup_steps=20,
                       schedule="wsd", microbatches=2,
                       checkpoint_dir=args.ckpt_dir, checkpoint_every=25)
    data = lm_data(cfg, batch=args.batch, seq_len=args.seq)
    writer = ckpt.AsyncCheckpointer(args.ckpt_dir, keep=2)
    t0 = time.time()

    def hook(step, state, metrics):
        if (step + 1) % tcfg.checkpoint_every == 0:
            writer.save(step + 1, state)
        if (step + 1) % 20 == 0:
            print(f"step {step + 1:4d} loss {metrics['loss']:.4f} "
                  f"lr {metrics['lr']:.2e} "
                  f"({(time.time() - t0) / (step + 1):.2f}s/step)")

    stop_at = int(args.steps * 0.6) if args.simulate_failure else None
    it = iter(data)
    if stop_at:
        tcfg_pre = dataclasses.replace(tcfg, steps=stop_at)
        state, hist = train_loop(model, cfg, tcfg_pre, it, hooks=[hook])
        writer.wait()
        print(f"\n-- simulated node failure at step {stop_at}; "
              f"restoring latest committed checkpoint --")
        step = ckpt.latest_step(args.ckpt_dir)
        like = init_state(model, jax.random.PRNGKey(tcfg.seed), tcfg)
        state = jax.tree.map(jax.numpy.asarray,
                             ckpt.restore(args.ckpt_dir, step, like))
        print(f"restored step {step}; resuming to {args.steps}")
        state, hist2 = train_loop(model, cfg, tcfg, it, state=state,
                                  hooks=[hook])
        hist += hist2
    else:
        state, hist = train_loop(model, cfg, tcfg, it, hooks=[hook])
    writer.close()
    first = np.mean([h["loss"] for h in hist[:10]])
    last = np.mean([h["loss"] for h in hist[-10:]])
    print(f"\nloss: {first:.4f} -> {last:.4f} over {len(hist)} steps "
          f"({(time.time() - t0):.0f}s total)")
    assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
