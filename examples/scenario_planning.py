"""Fleet-scale what-if planning with the batched scenario engine.

Plans an ensemble of Monte-Carlo swarm scenarios (mobility jitter, UAV
failures, log-normal shadowing) in one call, prints the robustness profile
of the nominal plan, and demonstrates instant failure delegation from the
precomputed contingency table wired into the fault-tolerance runner.

    PYTHONPATH=src python examples/scenario_planning.py [--scenarios 256]
"""
import argparse

import numpy as np

from repro.configs.lenet import LENET
from repro.core import RadioChannel, cnn_cost, make_devices
from repro.core.positions import hex_init
from repro.runtime.scenario_engine import (ContingencyTable, PositionSpec,
                                           ScenarioEngine, ScenarioGenerator)
from repro.runtime.serve_loop import PeriodicReplanner


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", type=int, default=256)
    ap.add_argument("--uavs", type=int, default=6)
    args = ap.parse_args()

    mc = cnn_cost(LENET)
    devs = make_devices(args.uavs)
    base = hex_init(args.uavs, 40.0)
    engine = ScenarioEngine(RadioChannel(), devs, mc)

    print(f"=== {args.scenarios} Monte-Carlo scenarios, {args.uavs} UAVs, "
          f"{len(mc.layers)} LeNet layers ===")
    gen = ScenarioGenerator(base, pos_sigma_m=3.0, failure_prob=0.05,
                            shadow_sigma_db=2.0, seed=0)
    plan = engine.plan_batch(gen.draw(args.scenarios))
    print(f"feasible scenarios : {plan.n_feasible}/{args.scenarios}")
    for q in (50, 90, 95, 99):
        print(f"  p{q:<2d} latency       : "
              f"{plan.latency_percentile(q) * 1e3:8.3f} ms")
    if plan.n_feasible:
        b = plan.best()
        print(f"best scenario      : #{b}  latency "
              f"{plan.latency[b] * 1e3:.3f} ms  power "
              f"{plan.total_power[b] * 1e3:.1f} mW")

    print("\n=== periodic re-optimization, amortized over the ensemble ===")
    rp = PeriodicReplanner(engine, gen, period=5,
                           n_scenarios=args.scenarios)
    for frame in range(10):
        refreshed = rp.tick(frame)
        if refreshed:
            print(f"  frame {frame}: refreshed — nominal "
                  f"{rp.nominal_latency * 1e3:.3f} ms, p95 "
                  f"{rp.robust_latency(95) * 1e3:.3f} ms, placement "
                  f"{tuple(int(x) for x in rp.assignment)}")

    print("\n=== fused P2: optimize positions on device in the same call ===")
    engine_p2 = ScenarioEngine(RadioChannel(), devs, mc,
                               position_spec=PositionSpec(steps=300))
    sparse = ScenarioGenerator(base * 3.0, pos_sigma_m=3.0, seed=1)
    plan_p2 = engine_p2.plan_batch(sparse.draw(args.scenarios))
    d = np.sqrt(((plan_p2.positions[:, :, None] -
                  plan_p2.positions[:, None, :]) ** 2).sum(-1))
    d[:, np.eye(args.uavs, dtype=bool)] = np.inf
    print(f"feasible scenarios : {plan_p2.n_feasible}/{args.scenarios} "
          f"(positions optimized from a 3x-spread swarm)")
    print(f"min separation     : {d.min():8.3f} m (constraint: 40 m)")
    print(f"p95 latency        : "
          f"{plan_p2.latency_percentile(95) * 1e3:8.3f} ms")

    print("\n=== precomputed failure contingencies (one batched call) ===")
    table = ContingencyTable(engine, base, source=0)
    for d in devs[:3]:
        cp = table.lookup([d.name])
        if cp is None:
            print(f"  {d.name} fails -> no feasible single-failure plan")
            continue
        # lookup() returns survivor-space indices; name them for the reader
        survivors = [x.name for x in devs if x.name != d.name]
        hosts = sorted({survivors[i] for i in cp.assign})
        print(f"  {d.name} fails -> delegate layers to {', '.join(hosts)}  "
              f"latency {cp.latency * 1e3:.3f} ms")
    print("\ndone.")


if __name__ == "__main__":
    main()
